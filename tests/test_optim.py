"""Optimizer + compression unit/property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.optim import (
    adam, apply_updates, clip_by_global_norm, ef_state_init, int8_compress,
    int8_decompress, momentum, sgd, warmup_cosine,
)


class TestOptimizers:
    def test_sgd_step(self):
        opt = sgd(0.1)
        p = {"w": jnp.ones((3,))}
        g = {"w": jnp.full((3,), 2.0)}
        st_ = opt.init(p)
        upd, st_ = opt.update(g, st_, p)
        p = apply_updates(p, upd)
        np.testing.assert_allclose(np.array(p["w"]), 1.0 - 0.2, rtol=1e-6)

    def test_adam_matches_reference(self):
        opt = adam(1e-2, b1=0.9, b2=0.999, eps=1e-8)
        p = {"w": jnp.zeros((4,))}
        st_ = opt.init(p)
        rng = np.random.default_rng(0)
        m = v = np.zeros(4)
        ref = np.zeros(4)
        for t in range(1, 6):
            g = rng.normal(size=4).astype(np.float32)
            upd, st_ = opt.update({"w": jnp.asarray(g)}, st_, p)
            p = apply_updates(p, upd)
            m = 0.9 * m + 0.1 * g
            v = 0.999 * v + 0.001 * g * g
            mh, vh = m / (1 - 0.9 ** t), v / (1 - 0.999 ** t)
            ref -= 1e-2 * mh / (np.sqrt(vh) + 1e-8)
        np.testing.assert_allclose(np.array(p["w"]), ref, rtol=1e-5)

    def test_bf16_state_dtype(self):
        opt = adam(1e-3, state_dtype=jnp.bfloat16)
        p = {"w": jnp.zeros((4,), jnp.bfloat16)}
        st_ = opt.init(p)
        assert st_["m"]["w"].dtype == jnp.bfloat16

    def test_clip(self):
        opt = clip_by_global_norm(sgd(1.0), 1.0)
        p = {"w": jnp.zeros((2,))}
        g = {"w": jnp.asarray([30.0, 40.0])}  # norm 50
        upd, _ = opt.update(g, opt.init(p), p)
        np.testing.assert_allclose(
            np.linalg.norm(np.array(upd["w"])), 1.0, rtol=1e-4)

    def test_warmup_cosine(self):
        f = warmup_cosine(1.0, 100, warmup_steps=10)
        assert float(f(jnp.asarray(0))) == 0.0
        np.testing.assert_allclose(float(f(jnp.asarray(10))), 1.0, rtol=1e-5)
        assert float(f(jnp.asarray(100))) < 1e-3


class TestCompression:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 1000), scale=st.floats(1e-4, 10.0))
    def test_quantisation_error_bound(self, seed, scale):
        rng = np.random.default_rng(seed)
        g = {"w": jnp.asarray(rng.normal(size=64) * scale, jnp.float32)}
        ef = ef_state_init(g)
        q, s, ne = int8_compress(g, ef)
        # residual bounded by one quantum
        assert float(jnp.max(jnp.abs(ne["w"]))) <= float(s["w"]) * 1.001

    def test_roundtrip_plus_error_is_exact(self):
        g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=32), jnp.float32)}
        ef = ef_state_init(g)
        q, s, ne = int8_compress(g, ef)
        deq = int8_decompress(q, s)
        np.testing.assert_allclose(
            np.array(deq["w"] + ne["w"]), np.array(g["w"]), atol=1e-6)
