"""Optimizer + compression unit/property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.optim import (
    adam, apply_updates, clip_by_global_norm, ef_state_init, int8_compress,
    int8_decompress, momentum, sgd, warmup_cosine,
)


class TestOptimizers:
    def test_sgd_step(self):
        opt = sgd(0.1)
        p = {"w": jnp.ones((3,))}
        g = {"w": jnp.full((3,), 2.0)}
        st_ = opt.init(p)
        upd, st_ = opt.update(g, st_, p)
        p = apply_updates(p, upd)
        np.testing.assert_allclose(np.array(p["w"]), 1.0 - 0.2, rtol=1e-6)

    def test_adam_matches_reference(self):
        opt = adam(1e-2, b1=0.9, b2=0.999, eps=1e-8)
        p = {"w": jnp.zeros((4,))}
        st_ = opt.init(p)
        rng = np.random.default_rng(0)
        m = v = np.zeros(4)
        ref = np.zeros(4)
        for t in range(1, 6):
            g = rng.normal(size=4).astype(np.float32)
            upd, st_ = opt.update({"w": jnp.asarray(g)}, st_, p)
            p = apply_updates(p, upd)
            m = 0.9 * m + 0.1 * g
            v = 0.999 * v + 0.001 * g * g
            mh, vh = m / (1 - 0.9 ** t), v / (1 - 0.999 ** t)
            ref -= 1e-2 * mh / (np.sqrt(vh) + 1e-8)
        np.testing.assert_allclose(np.array(p["w"]), ref, rtol=1e-5)

    def test_bf16_state_dtype(self):
        opt = adam(1e-3, state_dtype=jnp.bfloat16)
        p = {"w": jnp.zeros((4,), jnp.bfloat16)}
        st_ = opt.init(p)
        assert st_["m"]["w"].dtype == jnp.bfloat16

    def test_clip(self):
        opt = clip_by_global_norm(sgd(1.0), 1.0)
        p = {"w": jnp.zeros((2,))}
        g = {"w": jnp.asarray([30.0, 40.0])}  # norm 50
        upd, _ = opt.update(g, opt.init(p), p)
        np.testing.assert_allclose(
            np.linalg.norm(np.array(upd["w"])), 1.0, rtol=1e-4)

    def test_warmup_cosine(self):
        f = warmup_cosine(1.0, 100, warmup_steps=10)
        assert float(f(jnp.asarray(0))) == 0.0
        np.testing.assert_allclose(float(f(jnp.asarray(10))), 1.0, rtol=1e-5)
        assert float(f(jnp.asarray(100))) < 1e-3


class TestCompression:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 1000), scale=st.floats(1e-4, 10.0))
    def test_quantisation_error_bound(self, seed, scale):
        rng = np.random.default_rng(seed)
        g = {"w": jnp.asarray(rng.normal(size=64) * scale, jnp.float32)}
        ef = ef_state_init(g)
        q, s, ne = int8_compress(g, ef)
        # residual bounded by one quantum
        assert float(jnp.max(jnp.abs(ne["w"]))) <= float(s["w"]) * 1.001

    def test_roundtrip_plus_error_is_exact(self):
        g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=32), jnp.float32)}
        ef = ef_state_init(g)
        q, s, ne = int8_compress(g, ef)
        deq = int8_decompress(q, s)
        np.testing.assert_allclose(
            np.array(deq["w"] + ne["w"]), np.array(g["w"]), atol=1e-6)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 1000), k=st.integers(2, 8),
           scale=st.floats(1e-3, 10.0))
    def test_ef_sum_within_one_quantum(self, seed, k, scale):
        # the error-feedback guarantee: over K compressed steps the sum of
        # what the receiver reconstructs equals the sum of the raw
        # gradients up to the *final* residual, which is bounded by one
        # quantisation step — quantisation error does not accumulate
        rng = np.random.default_rng(seed)
        gs = [{"w": jnp.asarray(rng.normal(size=48) * scale, jnp.float32)}
              for _ in range(k)]
        ef = ef_state_init(gs[0])
        recv = np.zeros(48, np.float64)
        last_scale = 0.0
        for g in gs:
            q, s, ef = int8_compress(g, ef)
            recv += np.array(int8_decompress(q, s)["w"], np.float64)
            last_scale = float(s["w"])
        raw = np.sum([np.array(g["w"], np.float64) for g in gs], axis=0)
        # telescoping: raw - recv == final residual, |residual| <= scale
        np.testing.assert_allclose(raw - recv, np.array(ef["w"]), atol=1e-4)
        assert float(np.max(np.abs(raw - recv))) <= last_scale * 1.001

    def test_pallas_grad_quant_matches_compress_oracle(self):
        # the kernel and the XLA path (optim.compress) must implement the
        # same pack math — the delta-exchange payload is interchangeable
        from repro.kernels import ops

        rng = np.random.default_rng(3)
        g = jnp.asarray(rng.normal(size=512) * 0.3, jnp.float32)
        e = jnp.asarray(rng.normal(size=512) * 0.01, jnp.float32)
        qk, sk, nek = ops.grad_quant(g, e, block=128)
        qo, so, neo = int8_compress({"w": g}, {"w": e})
        np.testing.assert_allclose(np.asarray(sk).reshape(()),
                                   np.asarray(so["w"]).reshape(()),
                                   rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(qk).ravel(),
                                      np.asarray(qo["w"]).ravel())
        np.testing.assert_allclose(np.asarray(nek).ravel(),
                                   np.asarray(neo["w"]).ravel(), atol=1e-6)
